"""Heterogeneous-fleet benchmark: accuracy + wall clock vs straggler rate.

Sweeps ``straggler_rate`` over the fused hetero engine (``core.hetero``) at
D ∈ {16, 64, 256} on non-IID ``dirichlet_split`` shards — the scenario
family behind ``run_experiment(scenario="hetero")``.  Per (D, rate) the
payload records steady-state wall clock, dispatch count, final aggregated
accuracy, the accuracy delta vs the synchronous (rate 0) fleet, and the
measured staleness telemetry next to its analytic anchor p/(1−p).

The headline claim under test: straggler tolerance is FREE inside the
one-dispatch fused program — a straggling device trains the same scan (its
late delta is buffered, not recomputed), so a 30%-straggler round must
complete within 1.15x of the full-participation round's wall clock.  The
``acceptance`` entry in ``BENCH_hetero.json`` gates that at the largest
SWEPT fleet: D=256 on a full run (the ISSUE-4 criterion), D=16 on
``--quick`` (what the CI bench job runs).

    PYTHONPATH=src python -m benchmarks.run --only hetero [--quick]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

import jax

from repro.core import counters
from repro.core import hetero as hetero_mod
from repro.core.engine import EdgeEngine
from repro.core.federated import (MASSIVE_SAMPLES_PER_DEVICE,
                                  HETERO_DIRICHLET_ALPHA, Trainer,
                                  hetero_config)
from repro.core.hetero import HeteroConfig
from repro.data.digits import make_digit_dataset
from repro.data.federated_split import dirichlet_split

Row = Tuple[str, float, str]

WALL_CLOCK_LIMIT = 1.15       # straggler round vs full-participation round
ACCEPT_RATE = 0.3             # the gated straggler rate


def bench_hetero(quick: bool = False) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    sizes = [16] if quick else [16, 64, 256]
    rates = [0.0, 0.3] if quick else [0.0, 0.1, 0.3, 0.5]
    rounds = 3
    # "rate_grid" is the base sweep; each device_counts entry records the
    # rates it ACTUALLY swept ("swept_rates") — the biggest fleet only runs
    # the gated pair, and consumers must not assume the full grid exists
    payload: Dict = {"device_counts": {}, "rounds": rounds,
                     "rate_grid": rates,
                     "dirichlet_alpha": HETERO_DIRICHLET_ALPHA,
                     "samples_per_device": MASSIVE_SAMPLES_PER_DEVICE}

    for D in sizes:
        cfg = hetero_config(D)
        full = make_digit_dataset(MASSIVE_SAMPLES_PER_DEVICE * D, seed=0)
        test = make_digit_dataset(256, seed=1)
        seed_set = make_digit_dataset(cfg.initial_train, seed=2)
        shards = dirichlet_split(full, D, alpha=HETERO_DIRICHLET_ALPHA,
                                 seed=3)

        trainer = Trainer(cfg)
        params0 = trainer.init_params(jax.random.key(0))
        eng = EdgeEngine(trainer, cfg, shards, seed_set, test,
                         total_acquisitions=cfg.acquisitions * rounds)

        # the biggest fleet only needs the gated pair — keep the full-rate
        # sweep on the sizes where a (compile + 2 runs) cell is cheap
        d_rates = rates if D <= 64 else [0.0, ACCEPT_RATE]
        results: Dict[str, Dict] = {}
        for rate in d_rates:
            het = HeteroConfig(straggler_rate=rate, decay="exp",
                               decay_rate=0.5, buffer_stale=True,
                               slow_fraction=0.25, slow_steps_fraction=0.5)

            def run():
                state = eng.init_state(params0)
                counters.reset_dispatches()
                _, recs, final = eng.run_rounds_fused(state, rounds,
                                                      hetero=het)
                jax.block_until_ready(final)
                return recs

            run()                                  # warmup: compile
            t0 = time.perf_counter()
            recs = run()                           # steady state
            wall_ms = (time.perf_counter() - t0) * 1e3

            results[str(rate)] = {
                "wall_ms": wall_ms,
                "dispatches": counters.dispatch_count(),
                "final_acc": float(np.asarray(recs["agg_acc"])[-1]),
                "arrival_fraction": float(
                    np.asarray(recs["upload_mask"]).mean()),
                "staleness": hetero_mod.summarize_staleness(
                    recs["staleness"]),
                "expected_staleness": hetero_mod.expected_staleness(rate),
            }

        ref = results["0.0"]
        for rate_key, r in results.items():
            r["wall_ratio_vs_sync"] = r["wall_ms"] / max(ref["wall_ms"], 1e-9)
            r["acc_delta_pp_vs_sync"] = (r["final_acc"]
                                         - ref["final_acc"]) * 100.0
            rows.append((
                f"hetero/rate{rate_key}_D{D}", r["wall_ms"] * 1e3,
                f"acc={r['final_acc']:.3f},"
                f"wall_ratio={r['wall_ratio_vs_sync']:.2f}x,"
                f"stale_mean={r['staleness']['mean']:.2f}"))
        payload["device_counts"][D] = {"rates": results,
                                       "swept_rates": d_rates}

    # acceptance: the gated straggler rate completes within the wall-clock
    # limit of the synchronous round at the LARGEST swept fleet
    d_max = max(sizes)
    gated = payload["device_counts"][d_max]["rates"][str(ACCEPT_RATE)]
    payload["acceptance"] = {
        "criterion": f"{ACCEPT_RATE:.0%}-straggler round within "
                     f"{WALL_CLOCK_LIMIT}x of the full-participation fused "
                     f"round wall clock",
        "device_count": d_max,
        "wall_ratio": gated["wall_ratio_vs_sync"],
        "met": gated["wall_ratio_vs_sync"] <= WALL_CLOCK_LIMIT,
    }

    os.makedirs("experiments/results", exist_ok=True)
    with open("experiments/results/BENCH_hetero.json", "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return rows, payload
