"""Roofline table assembled from the dry-run artifacts (§Roofline).

Reads experiments/dryrun/*.json produced by repro.launch.dryrun and derives
per (arch × shape × mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS = 6·N(_active)·D, and the useful-compute ratio.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Tuple

Row = Tuple[str, float, str]

# (total params, active params) in units of 1e9, matmul-participating
# (embedding excluded for MODEL_FLOPS; MoE counts routed active experts).
_PARAMS = {
    "gemma2-2b": (2.0, 2.0),
    "recurrentgemma-9b": (8.0, 8.0),
    "gemma-7b": (7.8, 7.8),
    "whisper-small": (0.24, 0.24),
    "qwen3-8b": (7.0, 7.0),
    "deepseek-v2-236b": (234.0, 21.0),
    "arctic-480b": (474.0, 17.0),
    "llama-3.2-vision-11b": (10.0, 10.0),
    "minicpm3-4b": (3.8, 3.8),
    "mamba2-1.3b": (1.3, 1.3),
}

_TOKENS = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
           "decode_32k": 128, "long_500k": 1}


def model_flops(arch: str, shape: str) -> float:
    total, active = _PARAMS[arch]
    toks = _TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * active * 1e9 * toks          # fwd 2ND + bwd 4ND
    return 2.0 * active * 1e9 * toks              # inference forward


def load_records(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def bench_roofline(quick: bool = False) -> Tuple[List[Row], Dict]:
    rows, payload = [], {"records": []}
    for rec in load_records():
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec.get('mesh', '?')}"
        if rec.get("skipped"):
            rows.append((name, 0.0, "SKIP:" + rec["skipped"][:40]))
            continue
        if rec.get("error"):
            rows.append((name, 0.0, "ERROR"))
            continue
        if rec.get("mode", "baseline") != "baseline":
            name += "/" + rec["mode"]
        r = rec["roofline"]
        n_chips = 512 if rec["mesh"] == "2x16x16" else 256
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_global = rec["hlo_flops"] * n_chips
        useful = mf / hlo_global if hlo_global > 0 else float("nan")
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        derived = (f"bottleneck={r['bottleneck'].replace('_s','')};"
                   f"useful={useful:.2f};temp_gb={rec['per_device_bytes'].get('temp_gb', -1):.1f}")
        rows.append((name, step_s * 1e6, derived))
        payload["records"].append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "mode": rec.get("mode", "baseline"),
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": useful,
            "temp_gb": rec["per_device_bytes"].get("temp_gb"),
            "collective_counts": rec.get("collective_counts", {}),
        })
    return rows, payload
