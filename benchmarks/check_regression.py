"""CI wall-clock regression gate against a committed baseline.

Compares the wall-clock metrics named in ``benchmarks/baseline.json``
against the fresh payloads ``benchmarks.run --quick`` left under
``experiments/results/``; any metric above ``baseline × threshold``
(default 1.3×, per the CI contract) fails the gate with exit code 1.
Missing current results also fail — a bench that silently stopped running
is itself a regression.

The verdict is written to ``experiments/results/BENCH_regression.json``
(uploaded as a CI artifact next to the bench payloads).

Baseline format::

    {
      "threshold": 1.3,
      "host": "free-form provenance note",
      "metrics": {
        "<metric name>": {
          "file": "<payload under experiments/results/>",
          "path": ["json", "path", "segments"],
          "value": <baseline milliseconds>
        }
      }
    }

Wall-clock gates are host-sensitive: re-seed the baseline on the reference
runner with ``--update`` after intentional perf changes (or on first
deploy), and widen ``threshold`` via ``BENCH_BASELINE_TOLERANCE`` if the CI
fleet is noisy.

Usage:
    PYTHONPATH=src python -m benchmarks.run --quick
    python -m benchmarks.check_regression [--update] [--baseline PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS_DIR = "experiments/results"
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
DEFAULT_THRESHOLD = 1.3


def _extract(payload, path):
    node = payload
    for seg in path:
        if not isinstance(node, dict) or seg not in node:
            return None
        node = node[seg]
    return float(node) if isinstance(node, (int, float)) else None


def _current_value(metric, results_dir):
    fpath = os.path.join(results_dir, metric["file"])
    if not os.path.exists(fpath):
        return None
    with open(fpath) as f:
        return _extract(json.load(f), metric["path"])


def _validate_baseline(baseline, baseline_path):
    """Fail up front with EVERY schema problem listed, instead of a bare
    KeyError naming whichever key happened to be read first — a half-seeded
    baseline (e.g. a new bench without its baseline entry filled in) should
    tell the operator exactly which keys to add."""
    problems = []
    metrics = baseline.get("metrics")
    if not isinstance(metrics, dict):
        problems.append('top-level "metrics" table is missing or not a dict')
        metrics = {}
    for name, metric in metrics.items():
        if not isinstance(metric, dict):
            problems.append(f'metric "{name}" is not a dict')
            continue
        for key in ("file", "path", "value"):
            if key not in metric:
                problems.append(f'metric "{name}" is missing "{key}"')
        if "value" in metric and not isinstance(metric["value"], (int, float)):
            problems.append(
                f'metric "{name}" has non-numeric "value": {metric["value"]!r}'
            )
    if problems:
        schema = (
            '{"threshold": <float>, "metrics": {"<name>": '
            '{"file": "<payload>.json", "path": ["json", "path", ...], '
            '"value": <ms>}}}'
        )
        joined = "\n  - ".join(problems)
        print(
            f"invalid baseline {baseline_path}:\n  - {joined}\n\n"
            f"expected schema: {schema}",
            file=sys.stderr,
        )
        sys.exit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--results", default=RESULTS_DIR)
    ap.add_argument(
        "--update",
        action="store_true",
        help="re-seed baseline values from the current results",
    )
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    _validate_baseline(baseline, args.baseline)
    threshold = float(
        os.environ.get(
            "BENCH_BASELINE_TOLERANCE",
            baseline.get("threshold", DEFAULT_THRESHOLD),
        )
    )

    if args.update:
        missing = []
        for name, metric in baseline["metrics"].items():
            cur = _current_value(metric, args.results)
            if cur is None:
                missing.append(name)
            else:
                metric["value"] = round(cur, 3)
        if missing:
            print(
                f"cannot update, missing current results for: {missing}",
                file=sys.stderr,
            )
            sys.exit(1)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"baseline re-seeded: {args.baseline}")
        return

    verdicts = []
    failed = []
    for name, metric in baseline["metrics"].items():
        cur = _current_value(metric, args.results)
        ref = float(metric["value"])
        entry = {
            "metric": name,
            "baseline_ms": ref,
            "current_ms": cur,
            "limit_ms": round(ref * threshold, 3),
        }
        if cur is None:
            entry["status"] = "missing"
            failed.append(name)
        elif cur > ref * threshold:
            entry.update(status="regression", ratio=round(cur / ref, 3))
            failed.append(name)
        else:
            entry.update(status="ok", ratio=round(cur / ref, 3))
        verdicts.append(entry)
        print(
            f"{entry['status']:>10}  {name}: "
            f"{'n/a' if cur is None else f'{cur:.1f}ms'} "
            f"(baseline {ref:.1f}ms, limit {entry['limit_ms']:.1f}ms)"
        )

    os.makedirs(args.results, exist_ok=True)
    with open(os.path.join(args.results, "BENCH_regression.json"), "w") as f:
        json.dump(
            {"threshold": threshold, "failed": failed, "verdicts": verdicts},
            f,
            indent=2,
        )

    if failed:
        print(
            f"REGRESSION GATE FAILED (> {threshold:.2f}x): {failed}",
            file=sys.stderr,
        )
        sys.exit(1)
    print(
        f"regression gate passed ({len(verdicts)} metrics, "
        f"threshold {threshold:.2f}x)"
    )


if __name__ == "__main__":
    main()
