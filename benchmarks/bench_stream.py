"""Streaming-AL benchmark: score-driven vs random escalation on live
traffic.

Runs the live-traffic stream (``core.stream``) on the async event loop:
unlabeled requests arrive per simulated second with temporal label drift,
devices serve confident requests locally and escalate an ``escalate_k``
budget per event to the fog for labeling.  Two arms share IDENTICAL
traffic, rates, thresholds, and escalation budget (``escalate_threshold``
pinned to 0 so every queued request is eligible in both):

* ``selection="score"`` — the budget goes to the top-``escalate_k``
  requests by acquisition score (entropy), i.e. active learning on the
  stream;
* ``selection="random"`` — the SAME budget spent on uniformly random
  queued requests (the control arm).

Per (D, arm) the payload records host wall clock and dispatch count (the
one-dispatch contract holds with the stream fused in), offered load,
escalation count, serve accuracy, drop fraction, and the final aggregated
accuracy.

The headline claim under test: spending the labeling budget on the most
informative traffic beats spending it at random.  The ``acceptance``
entry in ``BENCH_stream.json`` gates ``final_acc(score) -
final_acc(random) >= ACC_ADVANTAGE_FLOOR_PP`` at equal escalation spend,
on the largest swept fleet (D=64 full, D=16 on ``--quick`` — the CI
bench job).

    PYTHONPATH=src python -m benchmarks.run --only stream [--quick]
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import replace
from typing import Dict, List, Tuple

import jax

from repro.core import counters
from repro.core.async_engine import async_telemetry
from repro.core.engine import EdgeEngine
from repro.core.federated import (HETERO_DIRICHLET_ALPHA,
                                  MASSIVE_SAMPLES_PER_DEVICE, Trainer,
                                  default_async, default_stream,
                                  stream_config)
from repro.core.stream import stream_telemetry
from repro.data.digits import make_digit_dataset
from repro.data.federated_split import dirichlet_split

Row = Tuple[str, float, str]

EVENTS = 6                    # fog aggregation events per run
ACC_ADVANTAGE_FLOOR_PP = 0.0  # score arm must not lose to random
ARMS = ("score", "random")


def bench_stream(quick: bool = False) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    sizes = [16] if quick else [16, 64]
    payload: Dict = {"device_counts": {}, "events": EVENTS,
                     "dirichlet_alpha": HETERO_DIRICHLET_ALPHA,
                     "samples_per_device": MASSIVE_SAMPLES_PER_DEVICE}

    for D in sizes:
        cfg = stream_config(D)
        full = make_digit_dataset(MASSIVE_SAMPLES_PER_DEVICE * D, seed=0)
        test = make_digit_dataset(256, seed=1)
        seed_set = make_digit_dataset(cfg.initial_train, seed=2)
        shards = dirichlet_split(full, D, alpha=HETERO_DIRICHLET_ALPHA,
                                 seed=3)

        acfg = default_async(D)
        # equal-budget comparison: escalate_threshold=0 makes EVERY queued
        # request eligible, so both arms spend min(escalate_k, queue) per
        # event — only the selection differs
        base = replace(default_stream(D), escalate_threshold=0.0, seed=0)
        extra = base.escalate_k * EVENTS
        total = cfg.acquisitions * EVENTS + extra
        trainer = Trainer(replace(cfg, acquisitions=total))
        params0 = trainer.init_params(jax.random.key(0))
        eng = EdgeEngine(trainer, cfg, shards, seed_set, test,
                         total_acquisitions=total)

        # selection is a static of the compiled loop: one warmup per arm,
        # then each timed run reuses its executable
        for arm in ARMS:
            eng.run_async(eng.init_state(params0), EVENTS, async_cfg=acfg,
                          stream=replace(base, selection=arm))

        arms: Dict[str, Dict] = {}
        for arm in ARMS:
            stream = replace(base, selection=arm)
            state = eng.init_state(params0)
            counters.reset_dispatches()
            t0 = time.perf_counter()
            _, recs, final = eng.run_async(state, EVENTS, async_cfg=acfg,
                                           stream=stream)
            jax.block_until_ready(final)
            wall_ms = (time.perf_counter() - t0) * 1e3

            atel = async_telemetry(recs)
            stel = stream_telemetry(recs,
                                    image_shape=test.images.shape[1:])
            cell = {
                "wall_ms": wall_ms,
                "dispatches": counters.dispatch_count(),
                "final_acc": atel["final_acc"],
                "sim_seconds_total": atel["sim_seconds_total"],
                "offered_total": stel["offered_total"],
                "escalated_total": stel["escalated_total"],
                "escalation_fraction": stel["escalation_fraction"],
                "serve_accuracy": stel["serve_accuracy"],
                "served_total": stel["served_total"],
                "drop_fraction": stel["drop_fraction"],
                "mean_queue_depth": stel["mean_queue_depth"],
                "escalation_uplink_bytes": stel["escalation_uplink_bytes"],
            }
            arms[arm] = cell
            rows.append((
                f"stream/D{D}_{arm}", wall_ms * 1e3,
                f"acc={cell['final_acc']:.3f},"
                f"esc={cell['escalated_total']},"
                f"serve_acc={cell['serve_accuracy']:.3f}"))

        arms["acc_advantage_pp"] = (
            arms["score"]["final_acc"]
            - arms["random"]["final_acc"]) * 100.0
        payload["device_counts"][D] = {"arms": arms,
                                       "stream": {
                                           "arrival_rate":
                                               base.arrival_rate,
                                           "rate_skew": base.rate_skew,
                                           "escalate_k": base.escalate_k,
                                           "drift_kappa": base.drift_kappa,
                                           "drift_period":
                                               base.drift_period}}

    # acceptance: at the largest swept fleet, score-driven escalation
    # keeps at least the floor over random at equal escalation spend
    d_max = max(sizes)
    gated = payload["device_counts"][d_max]["arms"]
    payload["acceptance"] = {
        "criterion": f"final_acc(selection=score) - final_acc(random) >= "
                     f"{ACC_ADVANTAGE_FLOOR_PP}pp at equal escalation "
                     f"budget ({EVENTS} events)",
        "device_count": d_max,
        "acc_advantage_pp": gated["acc_advantage_pp"],
        "escalated_score": gated["score"]["escalated_total"],
        "escalated_random": gated["random"]["escalated_total"],
        "met": gated["acc_advantage_pp"] >= ACC_ADVANTAGE_FLOOR_PP,
    }

    os.makedirs("experiments/results", exist_ok=True)
    with open("experiments/results/BENCH_stream.json", "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return rows, payload
