"""Hierarchical fog-topology benchmark: bytes + accuracy, flat vs two-tier.

Runs the fused engine at D ∈ {64, 256, 1024} (quick: D=16) on non-IID
``dirichlet_split`` shards — the ``run_experiment(scenario="fog")``
fleet — through one flat cell and one fog cell per group count
G ∈ {4, 16} (quick: G=4), with cloud sync every ``LOCAL_STEPS``-th
round.

Each cell records wall clock, jit dispatch count (the one-dispatch
contract holds with the fog tier on), final aggregated accuracy, and the
per-tier byte ledger from ``comms.tier_report``.  The headline claim
under test: the fog tier cuts the bytes crossing the upper
(fog→cloud) tier by ≥ ``UPLINK_CUT_MIN``x versus every-upload-to-cloud
flat federation, while accuracy (mean over the last two rounds — a
single round jitters ~1pp at CI sizes from the acquisition draw alone)
stays within ``ACC_DELTA_LIMIT_PP`` (2pp) of the flat run.  The ``acceptance`` entry
in ``BENCH_topology.json`` gates that at the largest swept size and
group count: D=1024/G=16 on a full run, D=16/G=4 on ``--quick`` (the CI
bench job).

    PYTHONPATH=src python -m benchmarks.run --only topology [--quick]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

import jax

from repro.core import comms as comms_mod
from repro.core import counters
from repro.core.engine import EdgeEngine
from repro.core.federated import (HETERO_DIRICHLET_ALPHA,
                                  MASSIVE_SAMPLES_PER_DEVICE, Trainer,
                                  fog_config)
from repro.core.topology import uniform_topology

Row = Tuple[str, float, str]

ACC_DELTA_LIMIT_PP = 2.0      # fog run vs flat run, final accuracy
UPLINK_CUT_MIN = 3.0          # fog→cloud bytes vs flat cross-tier bytes
LOCAL_STEPS = 2               # cloud sync cadence in the swept cells
ROUNDS = 4


def bench_topology(quick: bool = False) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    sizes = [16] if quick else [64, 256, 1024]
    groups = [4] if quick else [4, 16]
    payload: Dict = {"device_counts": {}, "rounds": ROUNDS,
                     "local_steps": LOCAL_STEPS,
                     "group_counts": groups,
                     "dirichlet_alpha": HETERO_DIRICHLET_ALPHA,
                     "samples_per_device": MASSIVE_SAMPLES_PER_DEVICE}

    from repro.data.digits import make_digit_dataset
    from repro.data.federated_split import dirichlet_split

    for D in sizes:
        cfg = fog_config(D)
        full = make_digit_dataset(MASSIVE_SAMPLES_PER_DEVICE * D, seed=0)
        test = make_digit_dataset(512, seed=1)
        seed_set = make_digit_dataset(cfg.initial_train, seed=2)
        shards = dirichlet_split(full, D, alpha=HETERO_DIRICHLET_ALPHA,
                                 seed=3)

        trainer = Trainer(cfg)
        params0 = trainer.init_params(jax.random.key(0))
        eng = EdgeEngine(trainer, cfg, shards, seed_set, test,
                         total_acquisitions=cfg.acquisitions * ROUNDS)

        cells = [("flat", None)]
        cells += [(f"fog_G{g}",
                   uniform_topology(D, g, local_steps=LOCAL_STEPS))
                  for g in groups if g <= D]

        results: Dict[str, Dict] = {}
        for name, topo in cells:

            def run():
                state = eng.init_state(params0)
                counters.reset_dispatches()
                _, recs, final = eng.run_rounds_fused(
                    state, ROUNDS, topology=topo)
                jax.block_until_ready(final)
                return recs, final

            run()                                  # warmup: compile
            t0 = time.perf_counter()
            recs, final = run()                    # steady state
            wall_ms = (time.perf_counter() - t0) * 1e3

            mask = np.asarray(recs["upload_mask"])
            accs = np.asarray(recs["agg_acc"])
            cell = {
                "wall_ms": wall_ms,
                "dispatches": counters.dispatch_count(),
                "final_acc": float(accs[-1]),
                # mean over the last two rounds: the gated statistic —
                # at CI sizes a single round's accuracy jitters by ~1pp
                # from the acquisition draw alone
                "acc_last2_mean": float(accs[-2:].mean()),
            }
            if topo is not None:
                tiers = comms_mod.tier_report(None, final, mask, topo)
                cell.update(
                    num_groups=topo.num_groups,
                    sync_rounds=tiers["sync_rounds"],
                    edge_fog_bytes=tiers["edge_fog_bytes_total"],
                    fog_cloud_bytes=tiers["fog_cloud_bytes_total"],
                    flat_cross_tier_bytes=tiers[
                        "flat_cross_tier_uplink_bytes"],
                    cross_tier_reduction=tiers["cross_tier_reduction"],
                )
            results[name] = cell

        flat = results["flat"]
        for name, r in results.items():
            r["acc_delta_pp_vs_flat"] = (r["acc_last2_mean"]
                                         - flat["acc_last2_mean"]) * 100.0
            cut = r.get("cross_tier_reduction", 1.0)
            rows.append((
                f"topology/{name}_D{D}", r["wall_ms"] * 1e3,
                f"acc={r['final_acc']:.3f},"
                f"delta_pp={r['acc_delta_pp_vs_flat']:+.1f},"
                f"uplink_cut={cut:.1f}x,"
                f"dispatches={r['dispatches']}"))
        payload["device_counts"][D] = {"cells": results}

    # acceptance: at the largest swept fleet and group count, the fog tier
    # cuts upper-tier uplink bytes >= UPLINK_CUT_MIN x while the final
    # accuracy stays within ACC_DELTA_LIMIT_PP of the flat run
    d_max = max(sizes)
    g_max = max(g for g in groups if g <= d_max)
    gated = payload["device_counts"][d_max]["cells"][f"fog_G{g_max}"]
    flat = payload["device_counts"][d_max]["cells"]["flat"]
    payload["acceptance"] = {
        "criterion": f"fog tier (G={g_max}, sync every {LOCAL_STEPS} "
                     f"rounds) cuts cross-tier uplink bytes >= "
                     f"{UPLINK_CUT_MIN}x at <= {ACC_DELTA_LIMIT_PP}pp "
                     f"final-accuracy cost vs flat federation",
        "device_count": d_max,
        "num_groups": g_max,
        "acc_flat": flat["acc_last2_mean"],
        "acc_fog": gated["acc_last2_mean"],
        "acc_delta_pp": gated["acc_delta_pp_vs_flat"],
        "cross_tier_reduction": gated["cross_tier_reduction"],
        "met": bool(gated["cross_tier_reduction"] >= UPLINK_CUT_MIN
                    and gated["acc_delta_pp_vs_flat"]
                    >= -ACC_DELTA_LIMIT_PP),
    }

    os.makedirs("experiments/results", exist_ok=True)
    with open("experiments/results/BENCH_topology.json", "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return rows, payload
