"""LM-fleet benchmark: the SSM adapter through the fused engine.

The ModelAdapter layer claims the engine core is model-agnostic; this
bench holds the LM regime (``scenario="lm"``: single-block Mamba-2 with a
carried per-device recurrent state, token shards from
``data.lm.lm_federated_split``) to the same three contracts the digit
fleets ship under:

* **active beats random** — score-driven acquisition must not lose to a
  random-selection control at the SAME label budget (the paper's
  active-vs-random claim on tokens);
* **one dispatch** — T fused AL rounds execute as exactly one host
  dispatch per arm (counter-asserted), with the adapter's
  ``aggregate_mask`` keeping ``recurrent/state`` out of Eq. 1 inside the
  compiled program;
* **vmap == mesh** — the shard_map mesh path reproduces the vmap path's
  final fog model to ≤ ``MESH_ATOL`` (the global-slot-0 excluded-leaf
  contract included).

The ``acceptance`` entry in ``BENCH_lm.json`` gates all three on the
largest swept fleet (D=16 full, D=8 on ``--quick`` — the CI bench job).

    PYTHONPATH=src python -m benchmarks.run --only lm [--quick]
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import replace
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import counters
from repro.core.engine import EdgeEngine
from repro.core.federated import (LM_SEQ_LEN, LM_VOCAB, Trainer, lm_config)
from repro.data.lm import lm_federated_split, make_lm_dataset
from repro.launch.mesh import make_device_mesh

Row = Tuple[str, float, str]

ROUNDS = 4                    # fused AL rounds per run
ACC_ADVANTAGE_FLOOR_PP = 0.0  # score arm must not lose to random
MESH_ATOL = 1e-5              # vmap vs shard_map final-model tolerance
ARMS = ("score", "random")


def _max_leaf_diff(a, b) -> float:
    return max(float(np.max(np.abs(np.asarray(la) - np.asarray(lb))))
               for la, lb in zip(jax.tree_util.tree_leaves(a),
                                 jax.tree_util.tree_leaves(b)))


def bench_lm(quick: bool = False) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    sizes = [8] if quick else [8, 16]
    payload: Dict = {"device_counts": {}, "rounds": ROUNDS,
                     "seq_len": LM_SEQ_LEN, "vocab": LM_VOCAB}

    for D in sizes:
        cfg = lm_config(D, seed=0)
        total = cfg.acquisitions * ROUNDS
        shards = lm_federated_split(D, 40, seq_len=LM_SEQ_LEN,
                                    vocab=LM_VOCAB, seed=0)
        test = make_lm_dataset(256, seq_len=LM_SEQ_LEN, vocab=LM_VOCAB,
                               seed=5, stream_seed=0)
        seed_set = make_lm_dataset(cfg.initial_train, seq_len=LM_SEQ_LEN,
                                   vocab=LM_VOCAB, seed=11, stream_seed=0)
        payload["device_counts"][D] = {"arms": {},
                                       "excluded": None, "mesh": None}

        arms: Dict[str, Dict] = {}
        final_by_arm: Dict[str, object] = {}
        for arm in ARMS:
            acq = "random" if arm == "random" else cfg.acquisition_fn
            cfg_arm = replace(cfg, acquisition_fn=acq)
            trainer = Trainer(cfg_arm)
            params0 = trainer.init_params(jax.random.key(0))
            eng = EdgeEngine(trainer, cfg_arm, shards, seed_set, test,
                             total_acquisitions=total)
            payload["device_counts"][D]["excluded"] = list(
                eng._exclude_paths(params0))

            # warmup compiles; the timed run reuses the executable
            eng.run_rounds_fused(eng.init_state(params0), ROUNDS)
            state = eng.init_state(params0)
            counters.reset_dispatches()
            t0 = time.perf_counter()
            _, recs, final = eng.run_rounds_fused(state, ROUNDS)
            jax.block_until_ready(final)
            wall_ms = (time.perf_counter() - t0) * 1e3
            final_by_arm[arm] = final

            cell = {
                "wall_ms": wall_ms,
                "dispatches": counters.dispatch_count(),
                "final_acc": float(recs["agg_acc"][-1]),
                "acc_trajectory": [float(a) for a in recs["agg_acc"]],
                "labels_total": float(np.asarray(
                    recs["n_labeled"][-1]).sum()),
            }
            arms[arm] = cell
            rows.append((
                f"lm/D{D}_{arm}", wall_ms * 1e3,
                f"acc={cell['final_acc']:.3f},"
                f"labels={cell['labels_total']:.0f},"
                f"dispatches={cell['dispatches']}"))

        # vmap == mesh on the score arm (the excluded-leaf contract holds
        # under shard_map: global slot 0's recurrent state wins)
        trainer = Trainer(cfg)
        params0 = trainer.init_params(jax.random.key(0))
        em = EdgeEngine(trainer, cfg, shards, seed_set, test,
                        total_acquisitions=total, mesh=make_device_mesh())
        _, _, fm = em.run_rounds_fused(em.init_state(params0), ROUNDS)
        mesh_diff = _max_leaf_diff(final_by_arm["score"], fm)
        rows.append((f"lm/D{D}_mesh", 0.0, f"max_diff={mesh_diff:.2e}"))

        arms["acc_advantage_pp"] = (
            arms["score"]["final_acc"]
            - arms["random"]["final_acc"]) * 100.0
        payload["device_counts"][D]["arms"] = arms
        payload["device_counts"][D]["mesh"] = {
            "host_devices": jax.device_count(),
            "max_final_model_diff": mesh_diff,
        }

    # acceptance: at the largest swept fleet — equal-budget advantage,
    # one dispatch per arm, and mesh == vmap on the final fog model
    d_max = max(sizes)
    gated = payload["device_counts"][d_max]
    one_dispatch = all(gated["arms"][a]["dispatches"] == 1 for a in ARMS)
    mesh_ok = gated["mesh"]["max_final_model_diff"] <= MESH_ATOL
    adv = gated["arms"]["acc_advantage_pp"]
    payload["acceptance"] = {
        "criterion": f"final_acc(score) - final_acc(random) >= "
                     f"{ACC_ADVANTAGE_FLOOR_PP}pp at equal label budget "
                     f"({ROUNDS} rounds); 1 dispatch/arm; "
                     f"vmap == mesh <= {MESH_ATOL}",
        "device_count": d_max,
        "acc_advantage_pp": adv,
        "one_dispatch": one_dispatch,
        "excluded_leaves": gated["excluded"],
        "mesh_max_diff": gated["mesh"]["max_final_model_diff"],
        "met": (adv >= ACC_ADVANTAGE_FLOOR_PP and one_dispatch and mesh_ok),
    }

    os.makedirs("experiments/results", exist_ok=True)
    with open("experiments/results/BENCH_lm.json", "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return rows, payload
