"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json

from benchmarks.roofline import load_records, model_flops


def fmt_bytes(gb):
    return f"{gb:.2f}"


def table(mesh: str, mode: str = "baseline", suffix: str = "") -> str:
    lines = [
        f"| arch | shape | compute s | memory s | collective s | bottleneck | "
        f"useful | temp GB | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records():
        if rec.get("skipped") or rec.get("error"):
            continue
        if rec["mesh"] != mesh or rec.get("mode", "baseline") != mode:
            continue
        if suffix and suffix not in rec.get("notes", ""):
            continue
        if not suffix and ("hints" in rec.get("notes", "")
                           or "lowp_ce" in rec.get("notes", "")):
            continue
        r = rec["roofline"]
        n_chips = 512 if mesh == "2x16x16" else 256
        mf = model_flops(rec["arch"], rec["shape"])
        useful = mf / (rec["hlo_flops"] * n_chips) if rec["hlo_flops"] else 0
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['bottleneck'].replace('_s', '')} | {useful:.2f} | "
            f"{rec['per_device_bytes'].get('temp_gb', float('nan')):.2f} | "
            f"{rec['compile_s']:.0f} |")
    return "\n".join(lines)


def skips() -> str:
    out = []
    for rec in load_records():
        if rec.get("skipped"):
            out.append(f"* {rec['arch']} × {rec['shape']}: {rec['skipped']}")
    return "\n".join(sorted(set(out)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    meshes = [args.mesh] if args.mesh else ["16x16", "2x16x16"]
    for mesh in meshes:
        print(f"\n### Mesh {mesh} (baseline)\n")
        print(table(mesh))
    print("\n### Skips\n")
    print(skips())


if __name__ == "__main__":
    main()
